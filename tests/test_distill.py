"""Distillation aggregation layer (ISSUE 5): heterogeneous-model federation.

Pins the tentpole guarantees: the flat (engine) fuse matches the tree
(reference) fuse to 1e-5, `build_scenario(model_mix=...)` trains 2+ cloud
rounds with finite loss on all three engines, homogeneous populations are
untouched (bit-identical to `model=`), and the group-aware plumbing
(cohort blocks, per-group accounting, public shard store) behaves.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hfl import HFLSchedule
from repro.data.synthetic_health import Dataset
from repro.engine import BatchedSyncEngine, DeviceShardStore, FlatPack, LocalJob, run_cohorts
from repro.engine.distill import (
    DistillSpec,
    check_distillable,
    distill_edge,
    distill_fuse_flat,
    kd_loss,
    soft_targets,
)
from repro.federated import build_scenario
from repro.federated.client import FLClient
from repro.federated.programs import (
    CNNProgram,
    FedSGDProgram,
    LMProgram,
    MLPProgram,
    group_clients,
)
from repro.federated.simulation import HeteroHFLSimulation
from repro.models.cnn1d import CNNConfig

MICRO_CNN = CNNConfig(in_channels=1, n_classes=3, seq_len=16, c1=4, c2=4, hidden=8)


def _micro_programs():
    return (
        CNNProgram(MICRO_CNN),
        MLPProgram(feat=(MICRO_CNN.seq_len, MICRO_CNN.in_channels), classes=3, hidden=4),
    )


@pytest.fixture(scope="module")
def mix_scenario():
    return build_scenario(
        "heartbeat", model_mix={"cnn": 12, "mlp": 6}, scale=0.02, seed=0,
        n_test_per_class=10,
    )


@pytest.fixture(scope="module")
def mix_assignment(mix_scenario):
    return mix_scenario.assign("eara-sca").lam


# -- program hooks -----------------------------------------------------------
def test_apply_logits_defaults_to_apply_and_fedsgd_delegates():
    cnn, mlp = _micro_programs()
    params = mlp.init(jax.random.PRNGKey(0))
    x = jnp.zeros((2,) + mlp.feat_shape, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(mlp.apply_logits(params, x)), np.asarray(mlp.apply(params, x))
    )
    wrapped = FedSGDProgram(base=mlp)
    np.testing.assert_array_equal(
        np.asarray(wrapped.apply_logits(params, x)), np.asarray(mlp.apply(params, x))
    )


def test_distill_spec_and_compatibility_validation():
    with pytest.raises(ValueError):
        DistillSpec(steps=0)
    with pytest.raises(ValueError):
        DistillSpec(batch=0)
    with pytest.raises(ValueError):
        DistillSpec(temperature=0.0)
    cnn, mlp = _micro_programs()
    check_distillable([cnn, mlp])  # shared alphabet + layout: fine
    with pytest.raises(ValueError):  # label alphabets differ
        check_distillable([cnn, MLPProgram(feat=(16, 1), classes=5)])
    with pytest.raises(ValueError):  # shard layouts differ
        check_distillable([cnn, LMProgram()])


def test_group_clients_partitions_by_program_value():
    cnn, mlp = _micro_programs()
    rng = np.random.default_rng(0)
    shard = Dataset(rng.normal(size=(3, 16, 1)).astype(np.float32),
                    np.zeros(3, np.int32), 3)
    # equal-by-value programs share a group even as distinct objects
    clients = [FLClient(0, shard, CNNProgram(MICRO_CNN)), FLClient(1, shard, mlp),
               FLClient(2, shard, cnn)]
    programs, group_of = group_clients(clients)
    assert [p.name for p in programs] == ["cnn", "mlp"]
    np.testing.assert_array_equal(group_of, [0, 1, 0])
    assert clients[0].program_name == "cnn"


# -- the fuse itself ---------------------------------------------------------
def _random_edge_state(seed, n_edges=3):
    """Per-group (E, D_g) matrices of slightly-perturbed inits."""
    programs = _micro_programs()
    packs = [FlatPack(p.init(jax.random.PRNGKey(0))) for p in programs]
    key = jax.random.PRNGKey(seed)
    mats = []
    for g, (prog, pack) in enumerate(zip(programs, packs)):
        rows = []
        for j in range(n_edges):
            k = jax.random.fold_in(key, g * 17 + j)
            rows.append(pack.ravel(prog.init(k)))
        mats.append(jnp.stack(rows))
    return programs, packs, mats


def test_fuse_flat_matches_tree_reference():
    """Acceptance pin: the engines' vmapped flat fuse reproduces the
    reference tree fuse within 1e-5 on identical inputs."""
    programs, packs, mats = _random_edge_state(seed=1, n_edges=3)
    spec = DistillSpec(steps=3, batch=5, temperature=2.0, lr=1e-2)
    rng = np.random.default_rng(7)
    xb = rng.normal(size=(3, spec.steps, spec.batch, 16, 1)).astype(np.float32)
    fused_flat, _ = distill_fuse_flat(
        programs, [pk.spec for pk in packs], mats, xb, spec
    )
    for j in range(3):
        fused_tree, _ = distill_edge(
            programs, [pk.unravel(m[j]) for pk, m in zip(packs, mats)], xb[j], spec
        )
        for g, pk in enumerate(packs):
            np.testing.assert_allclose(
                np.asarray(fused_flat[g][j]), np.asarray(pk.ravel(fused_tree[g])),
                atol=1e-5,
            )


def test_fuse_reduces_kd_loss():
    """Students move toward the ensemble: KD loss after the fuse is lower
    than before on the SAME public batch."""
    programs, packs, mats = _random_edge_state(seed=2, n_edges=1)
    spec = DistillSpec(steps=8, batch=16, lr=5e-2)
    rng = np.random.default_rng(3)
    xb = rng.normal(size=(1, spec.steps, spec.batch, 16, 1)).astype(np.float32)
    x0 = jnp.asarray(xb[0, 0])
    before_params = [pk.unravel(m[0]) for pk, m in zip(packs, mats)]
    targets = soft_targets(programs, before_params, x0, spec.temperature)
    fused, _ = distill_fuse_flat(programs, [pk.spec for pk in packs], mats, xb, spec)
    for g, (prog, pk) in enumerate(zip(programs, packs)):
        before = float(kd_loss(prog, before_params[g], x0, targets, spec))
        after = float(kd_loss(prog, pk.unravel(fused[g][0]), x0, targets, spec))
        assert after < before


# -- scenario wiring ---------------------------------------------------------
def test_model_mix_scenario_wiring(mix_scenario):
    sc = mix_scenario
    assert sc.is_hetero
    assert sc.name == "heartbeat-mix(cnn+mlp)"
    assert [c.program_name for c in sc.clients] == ["cnn"] * 12 + ["mlp"] * 6
    assert sc.public is not None and len(sc.public) == sc.n_edges
    assert all(len(p) > 0 for p in sc.public)
    assert isinstance(sc.distill, DistillSpec)


def test_model_mix_validation():
    with pytest.raises(ValueError):  # counts must sum to the population
        build_scenario("heartbeat", model_mix={"cnn": 3, "mlp": 3}, scale=0.02)
    with pytest.raises(ValueError):  # unknown program name
        build_scenario("heartbeat", model_mix={"cnn": 17, "nope": 1}, scale=0.02)
    with pytest.raises(ValueError):  # families cannot cross
        build_scenario("heartbeat", model_mix={"cnn": 17, "lm": 1}, scale=0.02)
    with pytest.raises(ValueError):  # fedsgd + mix unsupported
        build_scenario("heartbeat", model_mix={"cnn": 18}, fedsgd=True, scale=0.02)
    with pytest.raises(ValueError):  # model= and model_mix= conflict
        build_scenario("heartbeat", model="mlp", model_mix={"cnn": 12, "mlp": 6},
                       scale=0.02)
    with pytest.raises(ValueError):  # health mix cannot ride the lm dataset
        build_scenario("lm", model_mix={"cnn": 12, "mlp": 6}, scale=0.02)


def test_homogeneous_model_mix_bit_identical_to_model():
    """A single-entry mix is NOT a hetero population: no public pool is
    drawn, no fuse runs, and the trajectory is bit-identical to model=."""
    kw = dict(scale=0.02, seed=0, n_test_per_class=10)
    a = build_scenario("heartbeat", model="mlp", **kw)
    b = build_scenario("heartbeat", model_mix={"mlp": 18}, **kw)
    assert not b.is_hetero and b.public is None and b.distill is None
    asn = a.assign("eara-sca").lam
    ra = a.simulate(asn, cloud_rounds=1, seed=3, engine="sync")
    rb = b.simulate(asn, cloud_rounds=1, seed=3, engine="sync")
    for la, lb in zip(jax.tree.leaves(ra.final_params), jax.tree.leaves(rb.final_params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# -- end-to-end: 2 cloud rounds on every engine ------------------------------
def test_mixed_two_rounds_all_engines(mix_scenario, mix_assignment):
    """Acceptance: model_mix trains 2+ cloud rounds with FINITE loss on
    sync-device, sync-host, AND async, and the final params carry one tree
    per architecture."""
    for engine, kw in [
        ("sync", dict(pipeline="device")),
        ("sync", dict(pipeline="host")),
        ("async", {}),
    ]:
        res = mix_scenario.simulate(
            mix_assignment, cloud_rounds=2, seed=0, engine=engine, **kw
        )
        assert len(res.history) == 2
        for m in res.history:
            assert np.isfinite(m.mean_local_loss)
            assert 0.0 <= m.test_acc <= 1.0
        assert set(res.final_params) == {"cnn", "mlp"}


def test_mixed_engine_matches_reference(mix_scenario, mix_assignment):
    """Both sync pipelines reproduce the hetero reference simulator's
    trajectory (the reference trains each client with its own program and
    fuses with the tree-form distillation — this parity is the end-to-end
    correctness guarantee for the group-aware engine paths)."""
    ref = mix_scenario.simulate(
        mix_assignment, cloud_rounds=2, schedule=HFLSchedule(2, 1), seed=0
    )
    for pipeline in ("device", "host"):
        eng = mix_scenario.simulate(
            mix_assignment, cloud_rounds=2, schedule=HFLSchedule(2, 1), seed=0,
            engine="sync", pipeline=pipeline,
        )
        for mr, me in zip(ref.history, eng.history):
            assert me.test_acc == pytest.approx(mr.test_acc, abs=1e-6)
            assert me.mean_local_loss == pytest.approx(mr.mean_local_loss, abs=5e-3)
        assert eng.accountant.eu_bits_up == pytest.approx(ref.accountant.eu_bits_up)
        assert eng.accountant.eu_bits_down == pytest.approx(ref.accountant.eu_bits_down)
        assert eng.accountant.edge_rounds == ref.accountant.edge_rounds
        assert eng.accountant.edge_cloud_bits == pytest.approx(
            ref.accountant.edge_cloud_bits
        )


def test_mixed_accounting_per_group(mix_scenario, mix_assignment):
    """Each EU pays ITS architecture's payload: cnn clients the CNN model
    bits, mlp clients the (much smaller) MLP bits — up and down."""
    res = mix_scenario.simulate(
        mix_assignment, cloud_rounds=1, seed=0, engine="sync"
    )
    programs, group_of = group_clients(mix_scenario.clients)
    from repro.utils.tree import tree_size_bytes

    bits = [tree_size_bytes(p.init(jax.random.PRNGKey(0))) * 8 for p in programs]
    assert bits[0] != bits[1]  # the point of capability skew
    for i, c in enumerate(mix_scenario.clients):
        assert res.accountant.eu_bits_up[i] == pytest.approx(bits[group_of[i]])


def test_mixed_async_charges_group_payloads(mix_scenario, mix_assignment):
    res = mix_scenario.simulate(
        mix_assignment, cloud_rounds=1, seed=0, engine="async",
        quorum=1.0, staleness_decay=1.0,
    )
    sync = mix_scenario.simulate(
        mix_assignment, cloud_rounds=1, seed=0, engine="sync"
    )
    assert res.accountant.eu_bits_up == pytest.approx(sync.accountant.eu_bits_up)
    assert res.accountant.eu_bits_down == pytest.approx(sync.accountant.eu_bits_down)


def test_hetero_requires_public_shards(mix_scenario, mix_assignment):
    sc = mix_scenario
    with pytest.raises(ValueError):
        HeteroHFLSimulation(
            sc.clients, mix_assignment, sc.test, public=None, distill=DistillSpec()
        )
    with pytest.raises(ValueError):
        BatchedSyncEngine(
            sc.clients, mix_assignment, sc.program, sc.test,
            public_shards=None, distill=DistillSpec(),
        )


def test_mixed_without_distill_runs_independent_groups(mix_scenario, mix_assignment):
    """distill=None is a valid hetero federation (no knowledge transfer):
    groups evolve independently but everything still runs."""
    sim = HeteroHFLSimulation(
        mix_scenario.clients, mix_assignment, mix_scenario.test, seed=0
    )
    assert sim.distill is None
    res = sim.run(1)
    assert len(res.history) == 1 and np.isfinite(res.history[0].mean_local_loss)


# -- group-aware plumbing ----------------------------------------------------
def test_run_cohorts_mixed_blocks_bit_identical_to_solo():
    """Mixed-program job batches produce BIT-identical rows to running each
    architecture alone, and cross-block gathers are refused."""
    cnn, mlp = _micro_programs()
    rng = np.random.default_rng(0)
    shard = Dataset(rng.normal(size=(8, 16, 1)).astype(np.float32),
                    rng.integers(0, 3, 8).astype(np.int32), 3)
    clients = [FLClient(i, shard, p) for i, p in enumerate([cnn, mlp, cnn, mlp])]
    packs = {p: FlatPack(p.init(jax.random.PRNGKey(0))) for p in (cnn, mlp)}
    starts = {p: pk.ravel(p.init(jax.random.PRNGKey(1))) for p, pk in packs.items()}

    def jobs_for(cs):
        return [
            LocalJob(
                c, starts[c.program],
                [np.random.default_rng(100 + c.cid).integers(0, 8, (1, 10))],
                steps=1,
            )
            for c in cs
        ]

    mixed = run_cohorts(jobs_for(clients), cnn, packs[cnn])
    assert len(mixed.blocks) == 2
    solo_cnn = run_cohorts(jobs_for([clients[0], clients[2]]), cnn, packs[cnn])
    solo_mlp = run_cohorts(jobs_for([clients[1], clients[3]]), mlp, packs[mlp])
    for c, solo in [(clients[0], solo_cnn), (clients[2], solo_cnn),
                    (clients[1], solo_mlp), (clients[3], solo_mlp)]:
        np.testing.assert_array_equal(
            np.asarray(mixed.row(c.cid)), np.asarray(solo.row(c.cid))
        )
    with pytest.raises(ValueError):
        mixed.gather([0, 1])  # spans architecture blocks
    with pytest.raises(ValueError):
        mixed.matrix  # no single-matrix view of a mixed result


def test_store_from_shards_gather_matches_numpy():
    rng = np.random.default_rng(0)
    shards = [
        Dataset(rng.normal(size=(n, 6, 1)).astype(np.float32),
                rng.integers(0, 2, n).astype(np.int32), 2)
        for n in (3, 5, 2)
    ]
    store = DeviceShardStore.from_shards(shards)
    idx = np.stack([rng.integers(0, len(s), (2, 4)) for s in shards])
    xb, yb = store.gather(np.arange(3), idx)
    for j, s in enumerate(shards):
        np.testing.assert_array_equal(np.asarray(xb[j]), s.x[idx[j]])
        np.testing.assert_array_equal(np.asarray(yb[j]), s.y[idx[j]])


@pytest.mark.slow
def test_sequence_model_mix_smoke():
    """lm+moe capability mix on one token population: one cloud round,
    finite loss, per-group final params."""
    sc = build_scenario(
        model_mix={"lm": 4, "moe": 2}, lm_eus=6, lm_edges=2, scale=0.05,
        seed=0, n_test_per_class=8, lm_seq_len=16, lm_vocab=64,
    )
    assert sc.is_hetero and len(sc.public) == 2
    asn = sc.assign("dba").lam
    res = sc.simulate(asn, cloud_rounds=1, seed=0, engine="sync")
    assert np.isfinite(res.history[0].mean_local_loss)
    assert set(res.final_params) == {"lm", "moe"}
