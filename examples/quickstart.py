"""Quickstart: the paper's pipeline in ~40 lines.

Builds the Heartbeat scenario (Table 3 distribution), runs every assignment
strategy, and trains hierarchical FL for a few cloud rounds with the best.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.hfl import HFLSchedule
from repro.federated import build_scenario


def main() -> None:
    print("== building scenario (synthetic Heartbeat, 5 edges x 18 EUs) ==")
    sc = build_scenario("heartbeat", scale=0.03, seed=0, n_test_per_class=60)

    print("\n== assignment strategies (edge-level KLD, lower is better) ==")
    results = {}
    for strat in ("random", "dba", "eara-sca", "eara-dca", "eara-sca+"):
        a = sc.assign(strat)
        results[strat] = a
        print(f"  {strat:10s} KLD={a.kld_total:7.3f}  L1-obj={a.objective_l1:9.0f}")

    print("\n== hierarchical FL training (EARA-SCA vs DBA, 4 cloud rounds, T=4) ==")
    # T=4 edge rounds per cloud sync: with T=1 two-level FedAvg telescopes to
    # flat FedAvg and the assignment cannot matter (EXPERIMENTS.md §Validation)
    for strat in ("dba", "eara-sca"):
        res = sc.simulate(results[strat].lam, cloud_rounds=4,
                          schedule=HFLSchedule(local_steps=1, edge_per_cloud=4))
        accs = " ".join(f"{m.test_acc:.3f}" for m in res.history)
        traffic = np.mean(list(res.accountant.eu_traffic_bits().values())) / 8e6
        print(f"  {strat:10s} acc/round: {accs}   mean traffic {traffic:.2f} MB/EU")


if __name__ == "__main__":
    main()
