"""Hierarchical FL for LM training — the paper's pipeline on a non-CNN
workload, end to end.

Builds the topic-skewed token-stream population (``build_scenario(model=
...)``): each EU's shard is dominated by one Markov topic, the LM
counterpart of the paper's per-EU class imbalance.  EARA assigns EUs to
edges by their TOPIC histograms (same KLD objective, topics = classes),
then the batched sync engine trains the chosen sequence model — the dense
transformer-LM, the top-k-routed MoE, the hybrid attn+Mamba, or RWKV-6 —
through the device-resident round pipeline, the exact same engine code
that runs the paper's CNN.

  PYTHONPATH=src python examples/hfl_lm_training.py --rounds 3 --scale 0.1
  PYTHONPATH=src python examples/hfl_lm_training.py --model moe --rounds 2
"""
import argparse

from repro.federated import build_scenario


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lm", choices=["lm", "moe", "mamba", "rwkv"],
                    help="sequence program to train")
    ap.add_argument("--rounds", type=int, default=3, help="cloud rounds")
    ap.add_argument("--scale", type=float, default=0.1, help="sequences-per-EU scale")
    ap.add_argument("--eus", type=int, default=12)
    ap.add_argument("--edges", type=int, default=4)
    ap.add_argument("--topics", type=int, default=4)
    ap.add_argument("--engine", default="sync", choices=["reference", "sync", "async"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    sc = build_scenario(
        model=args.model, seed=args.seed, scale=args.scale, n_test_per_class=32,
        lm_eus=args.eus, lm_edges=args.edges, lm_topics=args.topics,
    )
    print(
        f"{args.model} population: {len(sc.clients)} EUs x "
        f"~{len(sc.clients[0].shard)} sequences, {args.topics} topics, "
        f"model {sc.model_bits / 8e3:.1f} kB"
    )
    eara = sc.assign("eara-sca")
    dba = sc.assign("dba")
    print(
        f"edge TOPIC imbalance (total KLD): eara-sca={eara.kld_total:.3f}  "
        f"dba={dba.kld_total:.3f}  (lower = better-mixed edges)"
    )
    res = sc.simulate(eara.lam, cloud_rounds=args.rounds, seed=args.seed,
                      engine=args.engine)
    for m in res.history:
        print(
            f"cloud round {m.cloud_round}: next-token acc={m.test_acc:.4f} "
            f"mean local loss={m.mean_local_loss:.3f}"
        )
    traffic = sum(res.accountant.eu_traffic_bits().values()) / 8e6
    print(f"done: {res.accountant.edge_rounds} edge rounds, "
          f"{traffic:.2f} MB total EU<->edge traffic")


if __name__ == "__main__":
    main()
