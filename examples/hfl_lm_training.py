"""Hierarchical FL for LM training — the paper's technique applied to the
assigned architectures (DESIGN.md Sec. 3 mapping).

Four edge replicas of a reduced LM train on topic-skewed token streams
(non-IID shards); edge level aggregates gradients every step (FedSGD),
the cloud syncs replicas every T steps.  EARA assigns topic shards to edges
by their token-class histograms, vs. a naive contiguous assignment.

  PYTHONPATH=src python examples/hfl_lm_training.py --steps 30 --T 5
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import dba_assignment, eara, total_kld_uniform
from repro.core.lp import solve_lp_eg
from repro.core.assignment import round_sca
from repro.data import TokenStream
from repro.distributed.hfl_mesh import init_hfl_state, make_hfl_train_step
from repro.models import init_params
from repro.training.optimizers import adam


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--T", type=int, default=5, help="cloud sync period")
    ap.add_argument("--edges", type=int, default=2)
    ap.add_argument("--shards", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    # non-IID shards: each stream has a dominant "topic" (token-class skew)
    streams = [TokenStream(cfg.vocab_size, seed=0, topic=i % 4) for i in range(args.shards)]
    hist = np.stack([
        np.bincount(s.batch(4, 256).ravel() % 16, minlength=16) for s in streams
    ])
    lam_frac = np.asarray(solve_lp_eg(jnp.asarray(hist, jnp.float32),
                                      jnp.asarray(np.ones((args.shards, args.edges), bool))))
    lam = round_sca(lam_frac, np.ones((args.shards, args.edges), bool))
    naive = np.zeros_like(lam)
    for i in range(args.shards):
        naive[i, i * args.edges // args.shards] = 1.0
    print("shard->edge KLD: EARA-style =",
          float(total_kld_uniform(jnp.asarray(lam), jnp.asarray(hist))),
          " naive contiguous =",
          float(total_kld_uniform(jnp.asarray(naive), jnp.asarray(hist))))

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adam(1e-3)
    state = init_hfl_state(params, opt, args.edges)
    local = jax.jit(make_hfl_train_step(cfg, opt, sync=False))
    sync = jax.jit(make_hfl_train_step(cfg, opt, sync=True))

    def edge_batch(assignment):
        batches = []
        for e in range(args.edges):
            members = np.nonzero(assignment[:, e])[0]
            s = streams[int(members[0])] if len(members) else streams[0]
            b = s.train_batch(4, 32)
            batches.append(b)
        return {
            k: jnp.stack([jnp.asarray(b[k]) for b in batches]) for k in batches[0]
        }

    for step_i in range(1, args.steps + 1):
        fn = sync if step_i % args.T == 0 else local
        state, m = fn(state, edge_batch(lam))
        if step_i % args.T == 0 or step_i == 1:
            print(f"step {step_i:3d} loss={float(m['total_loss']):.3f} "
                  f"edge_spread={float(m['edge_loss_spread']):.4f} "
                  f"{'(cloud sync)' if step_i % args.T == 0 else ''}")
    print("done: cross-edge traffic ran every", args.T, "steps instead of every step")


if __name__ == "__main__":
    main()
