"""End-to-end driver: the full I-Care hierarchical-FL experiment.

Reproduces the paper's Sec. 6 protocol end to end — synthetic data matching
Tables 2/3, wireless topology, EARA assignment + bandwidth allocation,
hierarchical training (T' local epochs, T edge rounds per cloud round),
divergence tracking vs the virtual-centralized model (eq. 17), and the
communication accounting behind Figs. 5/6.  A few hundred local gradient
steps total.

  PYTHONPATH=src python examples/hfl_healthcare.py [--dataset seizure]
                                                   [--rounds 8] [--scale 0.05]
"""
import argparse

import numpy as np

from repro.core.hfl import HFLSchedule
from repro.federated import build_scenario


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="heartbeat", choices=["heartbeat", "seizure"])
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--local-steps", type=int, default=1, help="T'")
    ap.add_argument("--edge-per-cloud", type=int, default=2, help="T")
    args = ap.parse_args()

    sc = build_scenario(args.dataset, scale=args.scale, seed=0, n_test_per_class=100)
    print(f"dataset={args.dataset}  EUs={len(sc.clients)}  edges={sc.n_edges}")
    print("per-EU class counts:\n", sc.class_counts)

    strategies = {}
    for strat in ("dba", "eara-sca", "eara-dca"):
        a = sc.assign(strat)
        strategies[strat] = a
        served = "n/a" if a.served is None else f"{a.served.mean():.0%}"
        print(f"\n{strat}: KLD={a.kld_total:.3f} served={served}")
        print("  assignment:", {i: list(np.nonzero(a.lam[i])[0]) for i in range(len(sc.clients))})

    sched = HFLSchedule(args.local_steps, args.edge_per_cloud)
    print(f"\nschedule: T'={sched.local_steps} T={sched.edge_per_cloud} "
          f"(cloud sync every {sched.cloud_period} local epochs)")

    for strat, a in strategies.items():
        res = sc.simulate(a.lam, cloud_rounds=args.rounds, schedule=sched,
                          track_divergence=(strat == "dba"), seed=0)
        print(f"\n== {strat} ==")
        for m in res.history:
            div = f" div={m.divergence:.3f}" if m.divergence else ""
            print(f"  cloud round {m.cloud_round:2d}: acc={m.test_acc:.3f} "
                  f"loss={m.mean_local_loss:.3f}{div}")
        acc = res.accountant
        print(f"  edge rounds={acc.edge_rounds} cloud rounds={acc.cloud_rounds} "
              f"edge<->cloud traffic={acc.edge_cloud_bits/8e6:.2f} MB "
              f"mean EU traffic={np.mean(list(acc.eu_traffic_bits().values()))/8e6:.2f} MB")

    cent = sc.centralized(args.rounds)
    print("\ncentralized benchmark acc:", " ".join(f"{m.test_acc:.3f}" for m in cent))


if __name__ == "__main__":
    main()
