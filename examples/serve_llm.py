"""Batched serving example: prefill + decode with KV/state caches.

Serves a reduced-config architecture (any of the 10 via --arch) on CPU:
prefills a batch of prompts, then greedily decodes new tokens, demonstrating
the serve path that the decode_32k / long_500k dry-run shapes lower.

  PYTHONPATH=src python examples/serve_llm.py --arch jamba-1.5-large-398b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, list_archs
from repro.models import init_params
from repro.models.transformer import decode_step, prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_seq = args.prompt_len + args.tokens
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_audio_frames, cfg.d_model)
        )

    print(f"arch={cfg.name} (smoke variant) batch={args.batch} "
          f"prompt={args.prompt_len} decode={args.tokens}")
    t0 = time.perf_counter()
    logits, cache = jax.jit(
        lambda p, t: prefill(p, cfg, t, max_seq=max_seq, **kw)
    )(params, prompts)
    logits.block_until_ready()
    print(f"prefill: {time.perf_counter()-t0:.2f}s "
          f"({args.batch * args.prompt_len} tokens)")

    step = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
        logits, cache = step(params, tok, cache, pos)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decode: {dt:.2f}s  ({args.batch*(args.tokens-1)/max(dt,1e-9):.1f} tok/s)")
    print("generated token ids (row 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
